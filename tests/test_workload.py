"""Workload subsystem: generator properties + the arrival-awareness
regression (a staggered request must never be served before it arrives).
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Cluster, SETUPS, SLO, summarize
from repro.core.request import Request
from repro.workload import (ChatbotLengths, DeterministicArrivals,
                            GammaArrivals, MixtureLengths,
                            PaperFixedLengths, PoissonArrivals,
                            RAGSharedPrefixLengths, RampArrivals,
                            ShareGPTLengths, WorkloadSpec, make_arrivals,
                            make_lengths, open_loop_workload)

from hypothesis_compat import given, settings, st

CFG = get_config("llama32-3b")

ALL_PROCESSES = (PoissonArrivals(4.0), GammaArrivals(4.0, cv=2.0),
                 RampArrivals(1.0, 8.0, ramp_s=5.0),
                 DeterministicArrivals(4.0))
ALL_MIXES = (PaperFixedLengths(), ShareGPTLengths(), ChatbotLengths(),
             RAGSharedPrefixLengths(),
             MixtureLengths(((0.6, ChatbotLengths()),
                             (0.4, RAGSharedPrefixLengths()))))


# ----------------------------------------------------------------------
# hypothesis property tests (skip gracefully without the dep)
# ----------------------------------------------------------------------
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(0, 200))
@settings(max_examples=25, deadline=None)
def test_arrivals_seed_deterministic_and_sorted(seed, n):
    for proc in ALL_PROCESSES:
        a = proc.times(n, seed=seed)
        b = proc.times(n, seed=seed)
        assert np.array_equal(a, b), type(proc).__name__
        assert a.shape == (n,)
        assert np.all(np.diff(a) >= 0.0)
        assert n == 0 or a[0] >= 0.0


@given(rate=st.floats(0.5, 50.0), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_poisson_mean_rate_converges(rate, seed):
    n = 4000
    t = PoissonArrivals(rate).times(n, seed=seed)
    # t[-1] ~ Gamma(n, 1/rate): relative sd = 1/sqrt(n) ~ 1.6%; 10% slack
    assert abs(n / t[-1] - rate) / rate < 0.10


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_length_mixes_deterministic_and_bounded(seed):
    for mix in ALL_MIXES:
        s1 = mix.sample(64, seed=seed)
        s2 = mix.sample(64, seed=seed)
        assert s1 == s2, type(mix).__name__
        for shape in s1:
            assert shape.prompt_len >= 1
            assert shape.output_len >= 1
            assert 0 <= shape.prefix_len <= shape.prompt_len


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_sharegpt_respects_clip_bounds(seed):
    mix = ShareGPTLengths()
    for shape in mix.sample(256, seed=seed):
        assert mix.prompt_min <= shape.prompt_len <= mix.prompt_max
        assert mix.output_min <= shape.output_len <= mix.output_max


# ----------------------------------------------------------------------
# plain unit tests (run with or without hypothesis)
# ----------------------------------------------------------------------
def test_deterministic_arrivals_ignore_seed():
    p = DeterministicArrivals(2.0)
    assert np.array_equal(p.times(10, seed=0), p.times(10, seed=99))
    assert np.allclose(np.diff(p.times(10)), 0.5)


def test_ramp_densifies_toward_rate1():
    t = RampArrivals(0.5, 8.0, ramp_s=20.0).times(200, seed=1)
    # the second half of the schedule must be much denser than the first
    mid = t[len(t) // 2]
    early = np.sum(t <= mid / 2)
    late = np.sum((t > mid / 2) & (t <= mid))
    assert late > early


def test_open_loop_workload_supports_every_arrival_kind():
    """Regression: arrival="ramp" used to crash (RampArrivals has no
    ``rate`` field); ``rate`` now maps to the ramp's terminal rate1."""
    for kind in ("poisson", "gamma", "deterministic", "ramp"):
        reqs = open_loop_workload(4.0, 6, arrival=kind,
                                  lengths=PaperFixedLengths(256, 4))
        assert len(reqs) == 6, kind
        arr = [r.arrival_s for r in reqs]
        assert arr == sorted(arr) and arr[0] >= 0.0
    # explicit ramp knobs still win over the derived defaults
    reqs = open_loop_workload(4.0, 6, arrival="ramp", rate0=0.5,
                              ramp_s=2.0,
                              lengths=PaperFixedLengths(256, 4))
    assert len(reqs) == 6


def test_registries_reject_unknown_names():
    with pytest.raises(ValueError, match="unknown arrival"):
        make_arrivals("weibull", rate=1.0)
    with pytest.raises(ValueError, match="unknown length"):
        make_lengths("the-pile")
    assert isinstance(make_arrivals("gamma", rate=2.0, cv=3.0),
                      GammaArrivals)
    assert isinstance(make_lengths("rag-shared-prefix"),
                      RAGSharedPrefixLengths)


def test_workload_spec_build_is_reproducible():
    spec = WorkloadSpec(arrivals=PoissonArrivals(3.0),
                        lengths=ShareGPTLengths(), n=16, seed=7,
                        slo=SLO(ttft_s=1.0, tpot_s=0.01), vocab_size=128)
    r1, r2 = spec.build(), spec.build()
    assert [(r.req_id, r.arrival_s, r.prompt_len, r.output_len)
            for r in r1] == \
           [(r.req_id, r.arrival_s, r.prompt_len, r.output_len)
            for r in r2]
    for a, b in zip(r1, r2):
        assert np.array_equal(a.prompt_tokens, b.prompt_tokens)
        assert a.slo.ttft_s == 1.0 and a.slo.tpot_s == 0.01
        assert a.slo is not b.slo        # no shared mutable SLO
    # req_id is the FCFS priority key: must follow arrival order
    arr = [r.arrival_s for r in r1]
    assert arr == sorted(arr)
    assert [r.req_id for r in r1] == list(range(16))


def test_rag_tenant_shares_token_prefix():
    spec = WorkloadSpec(arrivals=DeterministicArrivals(4.0),
                        lengths=RAGSharedPrefixLengths(prefix_len=64),
                        n=4, seed=0, vocab_size=997)
    reqs = spec.build()
    first = reqs[0].prompt_tokens[:64]
    for r in reqs[1:]:
        assert np.array_equal(r.prompt_tokens[:64], first)


# ----------------------------------------------------------------------
# the negative-TTFT regression (satellite fix): staggered arrivals on
# every setup must be admitted no earlier than they arrive
# ----------------------------------------------------------------------
@pytest.mark.parametrize("setup", SETUPS)
def test_staggered_arrivals_nonnegative_ttft(setup):
    reqs = open_loop_workload(0.5, 5, arrival="deterministic",
                              lengths=PaperFixedLengths(2048, 4), seed=0)
    assert all(r.arrival_s > 0 for r in reqs)      # genuinely staggered
    Cluster(setup, CFG).run(reqs)
    for r in reqs:
        assert r.prefill_start_s >= r.arrival_s, setup
        assert r.ttft_s is not None and r.ttft_s >= 0.0, \
            f"{setup}: negative TTFT {r.ttft_s}"
        assert r.finish_s >= r.first_token_s >= r.arrival_s


def test_idle_gap_arrivals_fast_forward_clock():
    """Arrivals far apart: each request is served on an otherwise idle
    engine whose clock must jump to the arrival instant, keeping TTFT
    identical to the lone-request TTFT."""
    reqs = open_loop_workload(0.01, 3, arrival="deterministic",
                              lengths=PaperFixedLengths(2048, 4))
    Cluster("co-1gpu", CFG).run(reqs)
    ttfts = [r.ttft_s for r in reqs]
    assert max(ttfts) - min(ttfts) < 1e-9          # no queueing between
    assert all(t >= 0 for t in ttfts)


# ----------------------------------------------------------------------
# tpot_s: single-token requests have no inter-token interval
# ----------------------------------------------------------------------
def test_single_token_request_tpot_is_none():
    reqs = open_loop_workload(4.0, 4, lengths=PaperFixedLengths(512, 1))
    Cluster("co-1gpu", CFG).run(reqs)
    assert all(r.generated == 1 for r in reqs)
    assert all(r.tpot_s is None for r in reqs)
    m = summarize(reqs)
    assert m.median_tpot_s == 0.0 and m.p99_tpot_s == 0.0


def test_summarize_excludes_single_token_from_tpot_percentiles():
    fast, slow_ = 0.002, 0.004
    reqs = []
    for i, tpot in enumerate((fast, slow_, None)):
        r = Request(req_id=i, prompt_len=8, output_len=1 if tpot is None
                    else 11, arrival_s=0.0)
        r.prefill_start_s = 0.0
        r.prefill_done_s = r.first_token_s = 0.1
        r.generated = 1 if tpot is None else 11
        r.finish_s = 0.1 if tpot is None else 0.1 + 10 * tpot
        reqs.append(r)
    m = summarize(reqs)
    # a 0.0 placeholder for the single-token request would have dragged
    # the median to `fast`; excluding it gives the mid of (fast, slow)
    assert m.median_tpot_s == pytest.approx((fast + slow_) / 2)
    assert m.num_requests == 3


def test_dvfs_sweep_accepts_workload_spec():
    """DVFS sweeps take a WorkloadSpec directly (satellite: sweeps
    accept a workload spec, not just a factory of t=0 batches)."""
    from repro.core.dvfs import sweep_frequencies
    spec = WorkloadSpec(arrivals=DeterministicArrivals(8.0),
                        lengths=PaperFixedLengths(2048, 4), n=4, seed=0)
    sw = sweep_frequencies("dis-ici", CFG, spec, freq_grid=(0.58, 1.0))
    assert set(sw.results) == {0.58, 1.0}
    assert all(p.latency_s > 0 for p in sw.prefill_points)
    # slowing the clock can only raise median TTFT (prefill compute-bound)
    assert sw.results[0.58].metrics.median_ttft_s \
        >= sw.results[1.0].metrics.median_ttft_s


def test_workload_metrics_open_loop_fields():
    reqs = open_loop_workload(2.0, 6, lengths=PaperFixedLengths(1024, 8),
                              slo=SLO(ttft_s=10.0, tpot_s=1.0))
    Cluster("dis-ici", CFG).run(reqs)
    m = summarize(reqs)
    assert m.num_requests == 6
    assert 0.0 < m.offered_rps < float("inf")
    assert m.slo_attainment == 1.0                 # SLOs are very loose
    assert m.goodput_rps > 0.0
    assert m.median_queue_s >= 0.0
