"""KV transfer paths: cost-model orderings + REAL byte-movement round
trips (including disk serialization) + hypothesis monotonicity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.transfer import DiskPath, HostPath, ICIPath, make_path


PATHS = [ICIPath(), HostPath(), DiskPath()]


def test_store_latency_ordering():
    """Paper F3: deeper memory tier => slower store (TTFT order)."""
    nbytes = int(1.8e9)    # one 16k-token llama KV payload
    ici, host, disk = (p.store_cost(nbytes).latency_s for p in PATHS)
    assert ici < host < disk


def test_fetch_latency_ordering():
    nbytes = int(1.8e9)
    ici, host, disk = (p.fetch_cost(nbytes).latency_s for p in PATHS)
    assert ici <= host < disk
    assert ici == 0.0      # pushed straight into decode HBM


def test_energy_deepens_with_tier():
    """Paper Fig 4: deeper tiers burn more non-accelerator energy."""
    nbytes = int(1.8e9)
    totals = [sum(p.store_cost(nbytes).energy_j.values())
              + sum(p.fetch_cost(nbytes).energy_j.values()) for p in PATHS]
    assert totals[0] < totals[1] < totals[2]
    assert "disk" in DiskPath().store_cost(nbytes).energy_j
    assert "dram" in HostPath().store_cost(nbytes).energy_j


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 10**10), st.integers(1, 10**10))
def test_costs_monotone_in_bytes(a, b):
    lo, hi = min(a, b), max(a, b)
    for p in PATHS:
        assert p.store_cost(lo).latency_s <= p.store_cost(hi).latency_s
        assert p.fetch_cost(lo).latency_s <= p.fetch_cost(hi).latency_s


# ----------------------------------------------------------------------
def _payload():
    k = jax.random.PRNGKey(0)
    return {
        "cache": jnp.asarray(jax.random.normal(k, (2, 1, 8, 2, 4)),
                             jnp.bfloat16),
        "state": jax.random.normal(jax.random.fold_in(k, 1), (1, 3, 3)),
        "logits": jax.random.normal(jax.random.fold_in(k, 2), (1, 17)),
    }


@pytest.mark.parametrize("name", ["ici", "host", "disk"])
def test_real_roundtrip_bit_exact(name, tmp_path):
    kw = {"scratch_dir": str(tmp_path)} if name == "disk" else {}
    path = make_path(name, **kw)
    payload = _payload()
    handle = path.store(payload)
    back = path.fetch(handle)
    for key in payload:
        np.testing.assert_array_equal(np.asarray(back[key]),
                                      np.asarray(payload[key]))
        assert back[key].dtype == payload[key].dtype


def test_disk_file_removed_after_fetch(tmp_path):
    import os
    path = DiskPath(scratch_dir=str(tmp_path))
    handle = path.store(_payload())
    assert os.path.exists(handle)
    path.fetch(handle)
    assert not os.path.exists(handle)
