"""Dry-run machinery smoke: one real (arch x shape x mesh) cell compiled
in a subprocess with 512 forced host devices (never in-process — the rest
of the suite must keep seeing 1 device)."""
import json
import os
import subprocess
import sys

import pytest


def _run_cell(arch, shape, mesh):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    code = (
        "import json\n"
        "from repro.launch.dryrun import run_cell\n"
        f"rec = run_cell({arch!r}, {shape!r}, {mesh}, verbose=False)\n"
        "rec.pop('traceback', None)\n"
        "print('REC:' + json.dumps(rec))\n")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("REC:")][0]
    return json.loads(line[4:])


@pytest.mark.slow
def test_single_pod_cell_compiles_with_roofline():
    rec = _run_cell("qwen2-0.5b", "decode_32k", False)
    assert rec["status"] == "ok", rec.get("error")
    r = rec["roofline"]
    assert r["flops"] > 0 and r["hbm_bytes"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
    assert rec["argument_bytes"] > 0


@pytest.mark.slow
def test_multi_pod_cell_compiles():
    rec = _run_cell("qwen2-0.5b", "decode_32k", True)
    assert rec["status"] == "ok", rec.get("error")
    assert rec["mesh"] == "2x16x16"


def test_skip_cells_are_recorded():
    # no jax device work needed for skips: run in-process via the module
    # logic (import is safe — only __main__ forces the flag... the module
    # sets XLA_FLAGS at import; so use a subprocess here too)
    rec = _run_cell("yi-34b", "long_500k", False)
    assert rec["status"] == "skip"
    assert "sub-quadratic" in rec["reason"]
