"""Perf-flag semantics: optimizations must preserve model outputs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import abstract_mesh
from repro.configs import REGISTRY, reduce_for_smoke
from repro.dist import opt_flags
from repro.dist.sharding import state_spec
from repro.models import get_model


@pytest.fixture(autouse=True)
def _reset_flags():
    yield
    opt_flags.set_flags("")


def test_unknown_flag_rejected():
    with pytest.raises(ValueError):
        opt_flags.set_flags("definitely_not_a_flag")


def test_flag_roundtrip():
    opt_flags.set_flags("remat_dots,bf16_logits")
    assert opt_flags.enabled("remat_dots")
    assert opt_flags.enabled("bf16_logits")
    assert not opt_flags.enabled("seq_shard_kv")
    opt_flags.set_flags("")
    assert not opt_flags.active()


@pytest.mark.parametrize("arch", ["moonshot-v1-16b-a3b", "qwen3-1.7b",
                                  "zamba2-2.7b"])
def test_opt_flags_preserve_forward(arch):
    cfg = reduce_for_smoke(REGISTRY[arch])
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 32), 0,
                              cfg.vocab_size)
    base = model.forward(params, {"tokens": toks})
    opt_flags.set_flags("local_moe_dispatch,remat_dots")
    tuned = model.forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(tuned, np.float32), atol=1e-5)


def test_opt_flags_preserve_grads():
    cfg = reduce_for_smoke(REGISTRY["moonshot-v1-16b-a3b"])
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.sample_batch(jax.random.PRNGKey(1), 2, 32)

    def loss(p):
        return model.loss(p, batch, remat=True)[0]

    g_base = jax.grad(loss)(params)
    opt_flags.set_flags("remat_dots,local_moe_dispatch")
    g_opt = jax.grad(loss)(params)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), g_base, g_opt)
    assert max(jax.tree.leaves(errs)) < 1e-4


def test_seq_shard_kv_changes_cache_spec():
    mesh = abstract_mesh((16, 16), ("data", "model"))
    kv_shape = (28, 128, 32768, 8, 128)
    base = state_spec(kv_shape, mesh)
    assert base[4] == "model" and base[2] is None
    opt_flags.set_flags("seq_shard_kv")
    tuned = state_spec(kv_shape, mesh)
    assert tuned[2] == "model" and tuned[4] is None
    # recurrent states (4-D) are unaffected
    assert state_spec((32, 128, 40, 64), mesh)[1] in ("data", ("data",))


def test_bf16_logits_keeps_dtype():
    cfg = reduce_for_smoke(REGISTRY["qwen3-1.7b"]).replace(
        param_dtype="bfloat16", compute_dtype="bfloat16")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 8), 0,
                              cfg.vocab_size)
    opt_flags.set_flags("bf16_logits")
    out = model.forward(params, {"tokens": toks})
    assert out.dtype == jnp.bfloat16
    opt_flags.set_flags("")
    out2 = model.forward(params, {"tokens": toks})
    assert out2.dtype == jnp.float32


def test_masked_cache_update_decode_equivalence():
    cfg = reduce_for_smoke(REGISTRY["qwen2-0.5b"])
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0,
                              cfg.vocab_size)
    _, state = model.prefill(params, {"tokens": toks[:, :15]}, s_max=16)
    pos = jnp.full((2,), 15, jnp.int32)
    base, _ = model.decode_step(params, toks[:, 15], state, pos)
    opt_flags.set_flags("masked_cache_update")
    _, state2 = model.prefill(params, {"tokens": toks[:, :15]}, s_max=16)
    tuned, _ = model.decode_step(params, toks[:, 15], state2, pos)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(tuned))


def test_flash_gqa_regroup_exact_over_head_configs():
    """pad_heads must be bit-exact for every (H, KV) shape class."""
    from repro.models import layers as L
    for H, KV in [(56, 8), (14, 2), (7, 1), (24, 8), (40, 8), (12, 4)]:
        B, S, hd = 1, 32, 16
        q = jax.random.normal(jax.random.PRNGKey(H), (B, S, H, hd))
        k = jax.random.normal(jax.random.PRNGKey(KV), (B, S, KV, hd))
        v = jax.random.normal(jax.random.PRNGKey(H + KV), (B, S, KV, hd))
        opt_flags.set_flags("")
        base = L.flash_gqa(q, k, v, causal=True)
        opt_flags.set_flags("pad_heads")
        tuned = L.flash_gqa(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(base), np.asarray(tuned),
                                   atol=1e-6, err_msg=f"H={H} KV={KV}")
    opt_flags.set_flags("")
