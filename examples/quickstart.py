"""Quickstart: the whole stack in one script, on CPU, in ~a minute.

1. Build a reduced model from the registry and run a forward pass.
2. Train it a few steps (real AdamW, real checkpointing).
3. Serve a batch through the paper's five setups and compare
   TTFT / TPOT / energy — the paper's Experiment 1 in miniature.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.core import Cluster, SETUPS, random_workload
from repro.launch.train import train
from repro.models import get_model


def main():
    # --- 1) a model from the zoo -------------------------------------
    cfg = reduce_for_smoke(get_config("llama32-3b"))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.sample_batch(jax.random.PRNGKey(1), 2, 32)
    logits = model.forward(params, batch)
    print(f"[1] {cfg.name} ({cfg.family}): forward -> {logits.shape}, "
          f"{model.param_count():,} params")

    # --- 2) train it a little ----------------------------------------
    losses, _ = train("llama32-3b", smoke=True, steps=20, batch_size=4,
                      seq_len=32, verbose=False)
    print(f"[2] trained 20 steps: loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    # --- 3) the paper's experiment, in miniature ----------------------
    cfg_full = get_config("llama32-3b")
    print(f"[3] serving 16 x (16384 in / 256 out) on TPU-target "
          f"cost model:")
    print(f"    {'setup':10s} {'TTFT':>8s} {'TPOT':>9s} {'J/token':>8s}")
    for setup in SETUPS:
        reqs = random_workload(16, input_len=16_384, output_len=256)
        res = Cluster(setup, cfg_full).run(reqs)
        m = res.metrics
        print(f"    {setup:10s} {m.median_ttft_s:7.2f}s "
              f"{m.median_tpot_s * 1e3:7.2f}ms "
              f"{res.joules_per_token:8.4f}")
    print("    (co-2gpus best TTFT; ici < host < disk — paper findings)")


if __name__ == "__main__":
    main()
