"""Fleet-scale serving demo: xP:yD pools + load-aware KV routing.

Builds one bursty open-loop workload (gamma arrivals over a ShareGPT-
style long-tail length mix) and serves it three ways:

  1. the P:D ratio story — a fixed 4-instance budget split 1P:3D,
     2P:2D, 3P:1D over ici, showing the goodput-optimal ratio;
  2. the router story — a 2-instance colocated pool balanced by the
     static round-robin split vs the least-outstanding-tokens policy
     (the fleet default), showing the p99 TTFT win on bursty traffic;
  3. per-instance utilization — busy seconds and energy per engine, the
     signal an autoscaler would act on.

  PYTHONPATH=src python examples/fleet_serving.py
  PYTHONPATH=src python examples/fleet_serving.py --rate 24 --n 64
"""
import argparse

from repro.configs import get_config
from repro.core import summarize
from repro.fleet import FleetCluster, FleetSpec
from repro.workload import (DEFAULT_INTERACTIVE_SLO, GammaArrivals,
                            ShareGPTLengths, WorkloadSpec, evaluate)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--rate", type=float, default=24.0)
    ap.add_argument("--cv", type=float, default=4.0,
                    help="arrival burstiness (gamma cv; 1 = Poisson)")
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    slo = DEFAULT_INTERACTIVE_SLO
    wl = WorkloadSpec(arrivals=GammaArrivals(args.rate, cv=args.cv),
                      lengths=ShareGPTLengths(prompt_sigma=1.5),
                      n=args.n, seed=args.seed, slo=slo)
    print(f"arch={cfg.name} rate={args.rate} req/s cv={args.cv} "
          f"n={args.n} (bursty long-tail workload)")

    print("\n-- P:D ratio at a fixed 4-instance budget (dis-ici)")
    for x, y in ((1, 3), (2, 2), (3, 1)):
        spec = FleetSpec.disaggregated(x, y, medium="ici")
        reqs = wl.build()
        res = FleetCluster(spec, cfg).run(reqs)
        rep = evaluate(reqs, slo)
        print(f"  {spec.name:9s} TTFT={res.metrics.median_ttft_s:6.3f}s "
              f"p99={res.metrics.p99_ttft_s:6.3f}s "
              f"TPOT={res.metrics.median_tpot_s * 1e3:6.2f}ms "
              f"goodput={rep.goodput_rps:5.2f} req/s")

    print("\n-- frontend router on a 2-instance colocated pool")
    for policy in ("round-robin", "least-outstanding-tokens"):
        spec = FleetSpec.colocated(2, router=policy)
        reqs = wl.build()
        FleetCluster(spec, cfg).run(reqs)
        m = summarize(reqs)
        print(f"  {policy:24s} p99 TTFT={m.p99_ttft_s:6.3f}s "
              f"median={m.median_ttft_s:6.3f}s")

    print("\n-- per-instance load on a 2P:2D ici fleet")
    cluster = FleetCluster(FleetSpec.disaggregated(2, 2, medium="ici"), cfg)
    reqs = wl.build()
    res = cluster.run(reqs)
    for e in cluster.engines:
        print(f"  {e.name} ({e.role:9s}) busy={e.busy_s:7.2f}s "
              f"steps={e.steps:5d} "
              f"energy={res.energy.joules.get(e.name, 0.0):8.1f} J")
    print("\nexpect: the balanced ratio wins goodput at this load; "
          "least-outstanding-tokens cuts p99 TTFT vs round-robin; "
          "prefill instances draw more energy, decode instances take "
          "far more (tiny) steps")


if __name__ == "__main__":
    main()
