"""Scheduler frontier demo (repro.sched, DESIGN.md section 17).

Three contrasts, each a couple of cached simulations:

1. serial vs chunked-interleave colocation at a rate where the serial
   composer's full-prefill stalls blow the interactive TPOT budget —
   chunking bounds every stall to one composed step and keeps goodput;
2. FCFS vs SRPT admission on a bimodal wave — short jobs jump the one
   long prefill that would otherwise head-of-line-block them;
3. the intra-GPU sixth setup vs dis-disk at the batch tier — same
   phase isolation, zero transfer joules.

  PYTHONPATH=src python examples/scheduler_frontier.py
"""
import argparse

from repro.core import SLO
from repro.exp import Experiment, run
from repro.workload import DEFAULT_INTERACTIVE_SLO

CHUNKED = {"composer": "chunked-interleave"}
BATCH_SLO = SLO(ttft_s=5.0, tpot_s=0.05)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--rate", type=float, default=4.5,
                    help="offered rate for the composer contrast "
                         "(default sits above serial's collapse)")
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # 1. composer contrast at the interactive SLO --------------------
    print(f"== composers: co-2gpus @ {args.rate} rps, interactive SLO "
          f"(ttft {DEFAULT_INTERACTIVE_SLO.ttft_s}s / tpot "
          f"{DEFAULT_INTERACTIVE_SLO.tpot_s * 1e3:.1f}ms)")
    for label, sched in (("serial", None), ("chunked-interleave", CHUNKED),
                         ("chunked + srpt", {**CHUNKED,
                                             "admission": "srpt"})):
        exp = Experiment.open("co-2gpus", args.rate, arch=args.arch,
                              n=args.n, seed=args.seed,
                              slo=DEFAULT_INTERACTIVE_SLO)
        if sched is not None:
            exp = exp.with_scheduler(sched)
        rec = run(exp)
        m = rec.metrics
        mixed = rec.energy_by_stage.get("mixed", 0.0)
        print(f"  {label:18s} goodput {rec.goodput['goodput_rps']:.3f} "
              f"rps  attain {rec.goodput['attainment']:.0%}  median "
              f"TPOT {m.median_tpot_s * 1e3:.2f}ms  mixed-stage "
              f"{mixed:.0f} J")

    # 2. admission contrast: one long prefill + a burst of shorts
    # (a hand-built bimodal wave, simulated directly — spec workloads
    # share one length mix, and the contrast needs two)
    print("\n== admission: 1 long (16k) + 6 short (256) jobs at t=0, "
          "co-1gpu")
    from repro.configs import get_config
    from repro.core.orchestrator import run_setup
    from repro.core.request import Request
    from repro.fleet import FleetSpec
    for admission in ("fcfs", "srpt"):
        reqs = [Request(req_id=0, prompt_len=16_384, output_len=16,
                        arrival_s=0.0)] + \
               [Request(req_id=i, prompt_len=256, output_len=16,
                        arrival_s=0.0) for i in range(1, 7)]
        spec = FleetSpec(n_colocated=1, scheduler=admission)
        run_setup(spec, get_config(args.arch), reqs)
        short_ft = max(r.first_token_s for r in reqs[1:])
        print(f"  {admission:5s} long first-token "
              f"{reqs[0].first_token_s:.3f}s  slowest short "
              f"{short_ft:.3f}s")

    # 3. intra-gpu vs dis-disk at the batch tier ---------------------
    print(f"\n== sixth setup: intra-gpu vs dis-disk @ 1 rps, batch SLO "
          f"(ttft {BATCH_SLO.ttft_s}s / tpot "
          f"{BATCH_SLO.tpot_s * 1e3:.0f}ms)")
    for setup in ("intra-gpu", "dis-disk"):
        rec = run(Experiment.open(setup, 1.0, arch=args.arch, n=args.n,
                                  seed=args.seed, slo=BATCH_SLO))
        es = rec.energy_by_stage
        xfer = es.get("transfer-store", 0.0) + es.get("transfer-fetch",
                                                      0.0)
        print(f"  {setup:9s} goodput {rec.goodput['goodput_rps']:.3f} "
              f"rps  transfer {xfer:.0f} J  total "
              f"{sum(es.values()):.0f} J")
    print("\nfull sweep + machine-checked claims: "
          "python -m benchmarks.fig11_scheduler_frontier --smoke")


if __name__ == "__main__":
    main()
