"""Open-loop serving demo: arrival processes, SLO goodput, crossover.

Builds a seed-deterministic Poisson workload over the paper's 16k/256
shape, serves it on all five setups at a low and a high offered rate,
and prints the load-dependent story the paper's caveat describes:
colocation wins while arrivals rarely overlap; once prefill-priority
interference kicks in, disaggregation over fast media takes the lead,
and the transfer-medium ordering (ici < host < disk) holds throughout.

  PYTHONPATH=src python examples/open_loop.py
  PYTHONPATH=src python examples/open_loop.py --rate 2 --rate 8 --n 24
"""
import argparse

from repro.configs import get_config
from repro.core import Cluster, SETUPS, SLO
from repro.workload import (DEFAULT_INTERACTIVE_SLO, PaperFixedLengths,
                            PoissonArrivals, WorkloadSpec, evaluate)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--rate", type=float, action="append", default=None,
                    help="offered Poisson rate, req/s (repeatable)")
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--ttft-slo", type=float,
                    default=DEFAULT_INTERACTIVE_SLO.ttft_s)
    ap.add_argument("--tpot-slo", type=float,
                    default=DEFAULT_INTERACTIVE_SLO.tpot_s)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    slo = SLO(ttft_s=args.ttft_slo, tpot_s=args.tpot_slo)
    rates = args.rate or [2.0, 8.0]
    print(f"arch={cfg.name} n={args.n} "
          f"slo: ttft<={slo.ttft_s}s tpot<={slo.tpot_s * 1e3}ms")
    for rate in rates:
        spec = WorkloadSpec(arrivals=PoissonArrivals(rate),
                            lengths=PaperFixedLengths(),
                            n=args.n, seed=args.seed, slo=slo)
        print(f"\n-- offered rate {rate} req/s "
              f"(same {args.n} requests on every setup)")
        for setup in SETUPS:
            reqs = spec.build()           # fresh, identical workload
            res = Cluster(setup, cfg).run(reqs)
            rep = evaluate(reqs)
            m = res.metrics
            print(f"  {setup:9s} TTFT={m.median_ttft_s:7.3f}s "
                  f"TPOT={m.median_tpot_s * 1e3:6.2f}ms "
                  f"queue={m.median_queue_s:6.3f}s "
                  f"attain={rep.attainment:5.0%} "
                  f"goodput={rep.goodput_rps:5.2f} req/s")
    print("\nexpect: co-2gpus leads goodput at the low rate; dis-ici "
          "overtakes at the high rate; dis-disk trails everywhere")


if __name__ == "__main__":
    main()
