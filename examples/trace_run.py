"""Observability demo: trace one simulated run and export it.

Serves a small Poisson workload on a disaggregated pair with a live
``repro.obs.Tracer`` attached, then shows every consumer of the event
stream: the Chrome/Perfetto trace JSON (open the written file at
https://ui.perfetto.dev), the terminal Gantt summary, the metrics
registry snapshot, and the per-request SLO-violation blame table.
Tracing is purely observational — run it twice with and without the
tracer and every metric matches bit-for-bit.

  PYTHONPATH=src python examples/trace_run.py
  PYTHONPATH=src python examples/trace_run.py --setup dis-disk --rate 1
  PYTHONPATH=src python -m benchmarks.report --trace trace_run.json
"""
import argparse
import json

from repro.configs import get_config
from repro.core import SLO
from repro.core.orchestrator import make_cluster
from repro.obs import (Tracer, attribute_run, blame_table, chrome_trace,
                       collect_run_metrics, text_summary,
                       transfer_queue_share, validate_chrome_trace)
from repro.workload import DEFAULT_INTERACTIVE_SLO, open_loop_workload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--setup", default="dis-host",
                    help="co-1gpu / co-2gpus / dis-ici / dis-host / "
                         "dis-disk, or a fleet shape like 2P2D-ici")
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="trace_run.json")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    slo = SLO(ttft_s=DEFAULT_INTERACTIVE_SLO.ttft_s,
              tpot_s=DEFAULT_INTERACTIVE_SLO.tpot_s)
    reqs = open_loop_workload(args.rate, args.n, slo=slo, seed=args.seed)

    tracer = Tracer()
    cluster = make_cluster(args.setup, cfg, tracer=tracer)
    cluster.run(reqs)

    # 1. Perfetto-loadable Chrome trace JSON
    payload = chrome_trace(tracer,
                           label=f"{args.setup} @ {args.rate} rps")
    validate_chrome_trace(payload)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {args.out} ({len(payload['traceEvents'])} events) — "
          "load it at https://ui.perfetto.dev\n")

    # 2. terminal Gantt summary of the same payload
    print(text_summary(payload))

    # 3. metrics registry snapshot (the RunRecord.obs block)
    snap = collect_run_metrics(cluster, reqs).snapshot()
    ttft = snap["histograms"]["request.ttft_s"]
    print(f"\nmetrics: {len(snap['counters'])} counters, "
          f"{len(snap['histograms'])} histograms; "
          f"request.ttft_s n={ttft['count']} sum={ttft['sum']:.3f}s")

    # 4. SLO blame: where each violating request's overrun went
    table = blame_table(attribute_run(reqs, slo, tracer))
    share = transfer_queue_share(table)
    print(f"SLO violations: {table['violations']}  "
          f"transfer+queue share: "
          f"{'n/a (no violations)' if share is None else f'{share:.2f}'}")
    for metric, row in sorted(table["metrics"].items()):
        if not row["violations"]:
            continue
        terms = ", ".join(f"{k}={v:.3f}s"
                          for k, v in sorted(row["terms"].items(),
                                             key=lambda kv: -kv[1])
                          if v > 0)
        print(f"  {metric}: {row['violations']} violations — {terms}")


if __name__ == "__main__":
    main()
