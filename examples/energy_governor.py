"""Energy-governance demo: online DVFS governors + the idle-power floor.

Serves one open-loop workload three ways (mirroring fleet_serving.py):

  1. the governor story — co-2gpus vs dis-ici under static phi=1.0,
     the queue-depth governor, and the DualScale-style SLO-slack
     governor, showing adaptive DVFS trimming ACTIVE joules while
     attainment holds;
  2. the idle-floor story — the per-state (active/idle) energy split
     from the power-state trace, showing why disaggregation stays more
     expensive no matter the policy: it holds more accelerator-seconds
     at static draw, which no frequency setting can scale away;
  3. the energy-aware router — a 2-prefill fleet with one instance
     downclocked, where the ``min-energy`` policy routes to the cheaper
     joules-per-token instance instead of the emptier queue.

  PYTHONPATH=src python examples/energy_governor.py
  PYTHONPATH=src python examples/energy_governor.py --rate 3 --n 32
"""
import argparse

from repro.configs import get_config
from repro.core import make_cluster
from repro.fleet import FleetCluster, FleetSpec
from repro.workload import (DEFAULT_INTERACTIVE_SLO, evaluate,
                            open_loop_workload)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--rate", type=float, default=3.0)
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    slo = DEFAULT_INTERACTIVE_SLO

    def wl():
        return open_loop_workload(args.rate, args.n, slo=slo,
                                  seed=args.seed)

    print(f"arch={cfg.name} rate={args.rate} req/s n={args.n} "
          f"SLO: TTFT<={slo.ttft_s}s TPOT<={slo.tpot_s * 1e3}ms")

    print("\n-- online governors vs static max frequency")
    results = {}
    for setup in ("co-2gpus", "dis-ici"):
        for policy, kw in (("static-1.0", {"phi": 1.0}),
                           ("queue-depth", {"governor": "queue-depth"}),
                           ("slo-slack", {"governor": "slo-slack"})):
            reqs = wl()
            cl = make_cluster(setup, cfg, **kw)
            res = cl.run(reqs)
            rep = evaluate(reqs, slo)
            decisions = sum(len(e.governor.decisions)
                            for e in cl.engines if e.governor)
            results[(setup, policy)] = res
            print(f"  {setup:9s} {policy:12s} "
                  f"E={res.energy.total_j:7.0f} J  "
                  f"goodput={rep.goodput_rps:5.2f} req/s  "
                  f"attain={rep.attainment:4.0%}  "
                  f"phi-changes={decisions}")

    print("\n-- the idle-power floor (per-state energy, phi=1.0 runs)")
    for setup in ("co-2gpus", "dis-ici"):
        res = results[(setup, "static-1.0")]
        summary = res.energy.trace.state_summary()
        accs = [c for c in summary if c.startswith("acc")]
        active = sum(summary[c]["active_j"] for c in accs)
        idle = sum(summary[c]["idle_j"] for c in accs)
        print(f"  {setup:9s} accelerator active={active:7.0f} J  "
              f"idle={idle:7.0f} J  (idle share "
              f"{idle / (active + idle):4.0%})")
    print("  expect: dis-ici's idle share exceeds co-2gpus' — the "
          "stage-siloed engines wait on each other; that floor, not "
          "active power, is why independent scaling can't save energy")

    print("\n-- min-energy routing on a heterogeneous 2P:1D fleet")
    for policy in ("least-outstanding-tokens", "min-energy"):
        spec = FleetSpec.disaggregated(2, 1, medium="ici",
                                       phi_prefill=(1.0, 0.58),
                                       router=policy)
        reqs = wl()
        cl = FleetCluster(spec, cfg)
        res = cl.run(reqs)
        rep = evaluate(reqs, slo)
        share = [round(e.busy_s, 1) for e in cl.prefill_engines]
        print(f"  {policy:24s} E={res.energy.total_j:7.0f} J  "
              f"goodput={rep.goodput_rps:5.2f}  "
              f"prefill busy_s fast/slow={share}")
    print("  expect: min-energy shifts work toward the downclocked "
          "instance (cheaper joules per token), trading a little "
          "latency for total energy")


if __name__ == "__main__":
    main()
