"""The paper's Experiment 2: DVFS latency-energy Pareto frontiers and the
stage-wise independent frequency question.

  PYTHONPATH=src python examples/dvfs_pareto.py
"""
from repro.configs import get_config
from repro.core import random_workload
from repro.core.dvfs import (best_independent, best_total_energy,
                             sweep_frequencies, sweep_independent)

GRID = (0.26, 0.42, 0.58, 0.74, 0.90, 1.0)


def main():
    cfg = get_config("llama32-3b")
    wl = lambda: random_workload(16, input_len=16_384, output_len=256)

    print("frequency sweep (batch 16, in 16384 / out 256):")
    sweeps = {}
    for setup in ("co-2gpus", "dis-ici"):
        sw = sweep_frequencies(setup, cfg, wl, freq_grid=GRID)
        sweeps[setup] = sw
        print(f"\n  {setup}: phi -> (TTFT, E_prefill) / (TPOT, E_decode)")
        for pp, dp in zip(sw.prefill_points, sw.decode_points):
            print(f"    {pp.phi:4.2f}  {pp.latency_s:6.2f}s "
                  f"{pp.energy_j / 1e3:6.2f}kJ   "
                  f"{dp.latency_s * 1e3:6.2f}ms {dp.energy_j / 1e3:6.2f}kJ")
        front = sw.prefill_frontier()
        print(f"    prefill Pareto frontier: "
              f"{[(p.phi, round(p.energy_j / 1e3, 2)) for p in front]}")

    co_best = best_total_energy(sweeps["co-2gpus"])
    print(f"\ncolocated best single-phi energy: "
          f"{co_best['energy_j'] / 1e3:.2f} kJ at phi="
          f"{co_best['phi_prefill']}")

    recs = sweep_independent("dis-ici", cfg, wl, freq_grid=GRID[::2])
    dis_best = best_independent(recs)
    print(f"dis-ici best independent pair: phi_p={dis_best['phi_prefill']}"
          f" phi_d={dis_best['phi_decode']} -> "
          f"{dis_best['energy_j'] / 1e3:.2f} kJ")
    verdict = ("saves energy" if dis_best["energy_j"] < co_best["energy_j"]
               else "does NOT save energy (the paper's takeaway 2)")
    print(f"independent frequency scaling {verdict}")


if __name__ == "__main__":
    main()
