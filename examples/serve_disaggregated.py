"""End-to-end disaggregated serving with REAL KV transfer (deliverable b).

Runs the same request batch through colocated and all three disaggregated
transfer paths with an actual (reduced) model executing on CPU: prefill on
engine 0, KV handoff through the medium (including a real disk round
trip), decode on engine 1 — and proves the token streams are identical.

  PYTHONPATH=src python examples/serve_disaggregated.py --arch rwkv6-3b
"""
import argparse

import jax

from repro.configs import get_config, reduce_for_smoke
from repro.core import Cluster, RealExecutor, SETUPS, random_workload
from repro.models import get_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b",
                    help="any zoo arch (dense/moe/ssm/hybrid)")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--input-len", type=int, default=48)
    ap.add_argument("--output-len", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = reduce_for_smoke(get_config(args.arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={model.param_count():,}")
    state_note = ("fixed-size recurrent state" if cfg.family == "ssm" else
                  "KV cache" if cfg.family != "hybrid" else
                  "mixed SSM state + shared-block KV")
    print(f"handoff payload: {state_note}")

    def factory(path):
        return RealExecutor(model, params, transfer_path=path)

    streams = {}
    for setup in SETUPS:
        reqs = random_workload(args.requests, input_len=args.input_len,
                               output_len=args.output_len,
                               vocab_size=cfg.vocab_size, seed=3)
        res = Cluster(setup, cfg, executor_factory=factory).run(reqs)
        ordered = sorted(res.requests, key=lambda r: r.req_id)
        streams[setup] = [r.output_tokens for r in ordered]
        m = res.metrics
        print(f"{setup:10s} TTFT={m.median_ttft_s:7.3f}s "
              f"TPOT={m.median_tpot_s * 1e3:7.2f}ms "
              f"tokens[req0]={streams[setup][0]}")

    base = streams["co-1gpu"]
    ok = all(s == base for s in streams.values())
    print("token streams identical across all setups:", ok)
    assert ok


if __name__ == "__main__":
    main()
