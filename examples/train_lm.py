"""Train a ~100M-class LM for a few hundred steps with checkpoint/restart
and straggler watching (deliverable b: end-to-end training driver).

Uses qwen2-0.5b reduced to ~smoke scale by default; pass --big for a
~100M-parameter variant (slower on CPU).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import os
import tempfile

from repro.configs import get_config, reduce_for_smoke
from repro.launch.train import train
from repro.models import get_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    cfg = reduce_for_smoke(get_config(args.arch))
    print(f"training {cfg.name} (reduced, "
          f"{get_model(cfg).param_count():,} params) for {args.steps} "
          f"steps; checkpoints in {ckpt}")

    losses, wd = train(args.arch, smoke=True, steps=args.steps,
                       batch_size=args.batch_size, seq_len=args.seq_len,
                       ckpt_dir=ckpt, ckpt_every=25, log_every=25)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(median step {wd.median_s * 1e3:.0f} ms, "
          f"{len(wd.flagged)} straggler steps)")
    print(f"resume any time with the same --ckpt-dir ({ckpt})")


if __name__ == "__main__":
    main()
