"""The Experiment API in one script: declare cells, expand a grid,
run it through the content-addressed cache, and re-run it for free.

One ``Experiment`` captures everything the paper's benchmark matrix
varies — setup (any xP:yD fleet), KV medium, load, frequency, governor,
SLO, seed — as a frozen, JSON-serializable spec whose sha256 is its
cache key. ``Grid`` cartesian-expands axes; ``run_grid`` dedupes,
serves hits from ``benchmarks/out/cache``, and fans misses out over a
process pool. Run the script twice: the second pass simulates nothing.

  PYTHONPATH=src python examples/experiment_grid.py
"""
import time

from repro.exp import Experiment, Grid, run, run_grid, sim_count
from repro.workload import DEFAULT_INTERACTIVE_SLO


def main():
    # --- one cell: declare, hash, run --------------------------------
    cell = Experiment.open("dis-ici", 4.0, n=16,
                           slo=DEFAULT_INTERACTIVE_SLO)
    print(f"[1] one cell {cell.setup} @ {cell.workload.rate} req/s "
          f"-> spec_hash {cell.spec_hash()[:12]}…")
    rec = run(cell)
    print(f"    attainment {rec.attainment:.2f}  "
          f"goodput {rec.goodput_rps:.2f} req/s  "
          f"{rec.joules_per_token:.4f} J/token")
    # the spec round-trips through JSON — ship it, archive it, diff it
    assert Experiment.from_json(cell.to_json()) == cell

    # --- a grid: setup x load x frequency ----------------------------
    grid = Grid(cell, {"setup": ("co-2gpus", "dis-ici", "dis-host"),
                       "rate": (2.0, 6.0),
                       "phi": (0.58, 1.0)})
    print(f"[2] grid: {len(grid)} cells "
          f"(3 setups x 2 rates x 2 phis), process-pool over misses")
    t0, s0 = time.time(), sim_count()
    recs = run_grid(grid, parallel=2)
    print(f"    ran {sim_count() - s0} simulations in "
          f"{time.time() - t0:.1f}s")
    print(f"    {'setup':10s} {'rate':>5s} {'phi':>5s} {'attain':>7s} "
          f"{'total_j':>9s}")
    for r in recs:
        phi = r.spec["fleet"]["phi_prefill"]
        rate = r.spec["workload"]["arrivals"]["rate"]
        print(f"    {r.setup:10s} {rate:5.1f} {phi:5.2f} "
              f"{r.attainment:7.2f} {r.total_j:9.0f}")

    # --- the cache: same grid again is pure reads --------------------
    t0, s0 = time.time(), sim_count()
    run_grid(grid)
    print(f"[3] warm rerun: {sim_count() - s0} simulations, "
          f"{time.time() - t0:.2f}s (content-addressed cache hits)")


if __name__ == "__main__":
    main()
